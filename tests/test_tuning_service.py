"""Fault-tolerant tuning service tests (ISSUE 7 acceptance).

Covers: the wire protocol's corruption armor, single-flight coalescing
(threads, processes, leader failure), the resilient client (deadline,
retry/backoff, circuit breaker, strict graceful degradation under every
fault class), generation-stamped invalidation of frozen tables, and the
chaos matrix: under injected server kill/delay/corrupt/drop/disconnect
faults every dispatch still returns correct params and no exception
ever escapes ``lookup_or_tune``.
"""
import json
import logging
import os
import subprocess
import sys
import threading
import time

import pytest

import repro.kernels  # noqa: F401  (registers dispatch problems)
from repro import tuning_cache
from repro.tuning_cache import TuningDatabase, registry
from repro.tuning_cache.service import (CORRUPT, DELAY, DISCONNECT, DROP,
                                        ERROR, ClientPolicy, FaultInjector,
                                        FaultSchedule, ServiceClient,
                                        ServiceFault, SingleFlight,
                                        TuningServer, parse_fault, protocol)

SIG = {"m": 320, "n": 320, "k": 320}       # off the pretuned grid: always
TARGET = "tpu-v5e"                         # a genuine cold tune server-side


def fast_policy(**over):
    kw = dict(deadline_s=5.0, connect_timeout_s=2.0, retries=1,
              backoff_base_s=0.01, backoff_max_s=0.02,
              breaker_threshold=100, breaker_cooldown_s=60.0)
    kw.update(over)
    return ClientPolicy(**kw)


@pytest.fixture(autouse=True)
def _fresh_state():
    """Isolate each test: fresh default db, no service, thawed tables."""
    tuning_cache.configure_service(None)
    tuning_cache.set_default_db(TuningDatabase())
    yield
    tuning_cache.configure_service(None)
    tuning_cache.reset_default_db()


@pytest.fixture()
def server():
    with TuningServer() as srv:
        yield srv


def local_params():
    """What the local default path answers for SIG (no service)."""
    return tuning_cache.lookup_or_tune("matmul", spec=TARGET, **SIG)


# ---------------------------------------------------------------------------
# protocol armor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("payload", [
    {"results": [{"params": {"bm": 1}}]},                   # no generation
    {"generation": True, "results": [{"params": {"bm": 1}}]},
    {"generation": 0, "results": "oops"},                   # not a list
    {"generation": 0, "results": []},                       # wrong length
    {"generation": 0, "results": [["not", "a", "dict"]]},
    {"generation": 0, "results": [{"params": {}}]},         # empty params
    {"generation": 0, "results": [{"params": "x"}]},
    {"generation": 0, "results": [{"no_params": 1}]},
])
def test_check_lookup_response_rejects_corruption(payload):
    with pytest.raises(ValueError):
        protocol.check_lookup_response(payload, 1)


def test_check_lookup_response_accepts_hits_and_errors():
    gen, out = protocol.check_lookup_response(
        {"generation": 3, "results": [{"params": {"bm": 8}, "digest": "d"},
                                      {"error": "unknown kernel"}]}, 2)
    assert gen == 3
    assert out[0]["params"] == {"bm": 8} and out[1] is None


def test_decode_rejects_non_objects():
    with pytest.raises(ValueError):
        protocol.decode(b"[1, 2, 3]")
    with pytest.raises(ValueError):
        protocol.decode(b'{"generation": }garbage')


# ---------------------------------------------------------------------------
# fault vocabulary
# ---------------------------------------------------------------------------


def test_fault_schedule_arithmetic():
    s = FaultSchedule(after=2, every=3, times=2)
    fired = 0
    hits = [h for h in range(1, 12)
            if s.fires_at(h, fired) and (fired := fired + 1)]
    assert hits == [2, 5]                   # after=2, stride 3, budget 2
    once = FaultSchedule(after=4, every=0)
    assert [h for h in range(1, 8) if once.fires_at(h, 0)] == [4]
    always = FaultSchedule()
    assert all(always.fires_at(h, h - 1) for h in range(1, 5))


def test_parse_fault():
    f = parse_fault("delay@server.tune:delay=2.0,after=3,times=1")
    assert (f.kind, f.site, f.delay_s) == (DELAY, "server.tune", 2.0)
    assert f.schedule == FaultSchedule(after=3, every=1, times=1)
    assert parse_fault("drop@client.request").schedule == FaultSchedule()
    for bad in ("drop", "drop@", "@site", "drop@site:delay",
                "drop@site:bogus=1", "nope@site"):
        with pytest.raises(ValueError):
            parse_fault(bad)


def test_injector_first_match_and_counters():
    inj = FaultInjector([ServiceFault("s", DROP,
                                      schedule=FaultSchedule(after=2))])
    assert inj.fire("s") is None            # hit 1: before `after`
    assert inj.fire("other") is None        # sites count independently
    assert inj.fire("s").kind == DROP
    assert inj.hits("s") == 2 and inj.fired == [("s", DROP)]


def test_scheduled_fault_adapts_to_train_supervisor_hook():
    from repro.runtime.fault import scheduled_fault
    inject = scheduled_fault(FaultSchedule(after=3, every=0),
                             exc=lambda step: OSError(f"step {step}"))
    inject(10)
    inject(11)
    with pytest.raises(OSError, match="step 12"):
        inject(12)
    inject(13)                              # budget-less after=3,every=0:
    #                                         fires exactly once


# ---------------------------------------------------------------------------
# single-flight coalescing
# ---------------------------------------------------------------------------


def test_singleflight_coalesces_threads():
    sf = SingleFlight()
    calls = []
    gate = threading.Event()

    def slow():
        calls.append(1)
        gate.wait(5)
        return "rec"

    results = []
    ts = [threading.Thread(target=lambda: results.append(sf.do("k", slow)))
          for _ in range(6)]
    for t in ts:
        t.start()
    time.sleep(0.2)                         # let racers park on the event
    gate.set()
    for t in ts:
        t.join(5)
    assert len(calls) == 1                  # fn ran exactly once
    assert [r[0] for r in results] == ["rec"] * 6
    assert sum(1 for r in results if r[1]) == 1     # one leader


def test_singleflight_leader_failure_reelects():
    """A failed leader must not fan its error out to parked racers —
    they re-elect and run the callable themselves."""
    sf = SingleFlight()
    entered, release = threading.Event(), threading.Event()

    def failing():
        entered.set()
        release.wait(5)
        raise RuntimeError("leader dies")

    leader_error, racer_result = [], []

    def leader():
        try:
            sf.do("k", failing)
        except RuntimeError as e:
            leader_error.append(e)

    t1 = threading.Thread(target=leader)
    t1.start()
    assert entered.wait(5)
    t2 = threading.Thread(
        target=lambda: racer_result.append(sf.do("k", lambda: "fresh")))
    t2.start()
    time.sleep(0.1)                         # racer parks on the flight
    release.set()
    t1.join(5)
    t2.join(5)
    assert len(leader_error) == 1           # the leader saw its own error
    assert racer_result and racer_result[0][0] == "fresh"


def test_server_coalesces_concurrent_client_threads(server):
    server.injector.add(parse_fault("delay@server.tune:delay=0.5,times=1"))
    client = ServiceClient(server.url, policy=fast_policy())
    barrier = threading.Barrier(6)
    results = []

    def worker():
        barrier.wait(5)
        results.append(client.resolve("matmul", SIG, target=TARGET))

    ts = [threading.Thread(target=worker) for _ in range(6)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(15)
    client.close()
    assert len(results) == 6 and all(r is not None for r in results)
    assert len({json.dumps(r["params"], sort_keys=True)
                for r in results}) == 1
    assert server.stats.tunes == 1          # exactly one rank ran
    assert server.stats.coalesced >= 1


# ---------------------------------------------------------------------------
# client resilience
# ---------------------------------------------------------------------------


def test_roundtrip_matches_local_params(server):
    client = ServiceClient(server.url, policy=fast_policy())
    res = client.resolve("matmul", SIG, target=TARGET)
    assert res is not None and res["params"] == local_params()
    assert res["space_size"] > 0 and res["source"] == "static"
    assert client.stats.hits == 1 and client.stats.failures == 0
    health = client.health()
    assert health["ok"] and health["records"] >= 1
    stats = client.remote_stats()
    assert stats["server"]["tunes"] == 1
    client.close()


def test_batch_mixes_hits_and_definitive_misses(server):
    client = ServiceClient(server.url, policy=fast_policy())
    out = client.resolve_batch([
        {"kernel_id": "matmul", "signature": SIG, "target": TARGET},
        {"kernel_id": "no_such_kernel", "signature": {}, "target": TARGET},
    ])
    assert out[0] is not None and out[1] is None
    # a definitive miss is NOT a transport failure: breaker untouched
    assert client.stats.failures == 0 and client.stats.misses == 1
    assert client.breaker.state == client.breaker.CLOSED
    client.close()


def test_fingerprint_mismatch_is_miss_not_failure(server):
    client = ServiceClient(server.url, policy=fast_policy())
    res = client.resolve("matmul", SIG, target=TARGET,
                         fingerprint="tpu-v5e@000000000000")
    assert res is None
    assert client.stats.misses == 1 and client.stats.failures == 0
    client.close()


def test_dead_server_degrades_to_local_tiers():
    client = ServiceClient("http://127.0.0.1:9",        # nothing listens
                           policy=fast_policy(deadline_s=2.0))
    tuning_cache.configure_service(client=client)
    params = local_params()                 # must not raise, must answer
    assert params and client.stats.degraded >= 1
    # the local answer primed the memo: repeats never re-consult the
    # dead service
    requests0 = client.stats.requests
    assert local_params() == params
    assert client.stats.requests == requests0


def test_retry_backoff_then_success(server):
    inj = FaultInjector([parse_fault("error@client.request:times=2")])
    client = ServiceClient(server.url, injector=inj,
                           policy=fast_policy(retries=3))
    res = client.resolve("matmul", SIG, target=TARGET)
    assert res is not None                  # third attempt lands
    assert client.stats.retries == 2 and client.stats.failures == 2
    assert client.breaker.state == client.breaker.CLOSED
    client.close()


def test_circuit_breaker_trips_half_opens_recovers(server):
    now = [0.0]
    inj = FaultInjector([parse_fault("error@client.request:times=3")])
    client = ServiceClient(
        server.url, injector=inj, clock=lambda: now[0],
        policy=fast_policy(retries=1, breaker_threshold=2,
                           breaker_cooldown_s=10.0, backoff_base_s=0.0,
                           jitter=0.0))
    assert client.resolve("matmul", SIG, target=TARGET) is None
    assert client.breaker.state == client.breaker.OPEN
    assert client.breaker.trips == 1 and client.stats.failures == 2
    # open: short-circuit without touching the network
    attempts0 = client.stats.attempts
    assert client.resolve("matmul", SIG, target=TARGET) is None
    assert client.stats.attempts == attempts0
    # cooldown elapses -> half-open admits exactly ONE probe (no
    # retries while half-open), which eats the last budgeted fault and
    # re-opens the circuit
    now[0] += 10.0
    assert client.breaker.state == client.breaker.HALF_OPEN
    assert client.resolve("matmul", SIG, target=TARGET) is None
    assert client.breaker.state == client.breaker.OPEN
    assert client.breaker.trips == 2
    # next half-open probe succeeds (budget exhausted) -> CLOSED
    now[0] += 10.0
    res = client.resolve("matmul", SIG, target=TARGET)
    assert res is not None and res["params"] == local_params()
    assert client.breaker.state == client.breaker.CLOSED
    client.close()


def test_degradation_logs_once_per_kernel(caplog):
    client = ServiceClient("http://127.0.0.1:9",
                           policy=fast_policy(retries=0, deadline_s=1.0))
    with caplog.at_level(logging.WARNING,
                         logger="repro.tuning_cache.service.client"):
        client.resolve("matmul", SIG, target=TARGET)
        client.resolve("matmul", SIG, target=TARGET)
        client.resolve("matvec", {"m": 128, "n": 128}, target=TARGET)
    warnings = [r.getMessage() for r in caplog.records
                if r.levelno >= logging.WARNING]
    assert len(warnings) == 2               # one per kernel, not per call
    assert any("matmul" in w for w in warnings)
    assert any("matvec" in w for w in warnings)


def test_unserializable_signature_degrades():
    client = ServiceClient("http://127.0.0.1:9", policy=fast_policy())
    out = client.resolve("matmul", {"m": object()}, target=TARGET)
    assert out is None
    assert client.stats.attempts == 0       # never hit the wire


# ---------------------------------------------------------------------------
# chaos matrix: every fault class degrades, nothing escapes dispatch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fault", [
    "drop@server.request",
    "delay@server.request:delay=0.05",
    "corrupt@server.request",
    "disconnect@server.request",
    "error@server.request",
    "error@client.request",
    "corrupt@client.request",
])
def test_chaos_dispatch_always_answers(fault):
    expected = local_params()
    tuning_cache.set_default_db(TuningDatabase())   # re-cold the local db
    inj = FaultInjector([parse_fault(fault)])
    client_inj = inj if fault.endswith("client.request") else None
    server_inj = inj if client_inj is None else None
    with TuningServer(injector=server_inj) as srv:
        client = ServiceClient(srv.url, injector=client_inj,
                               policy=fast_policy(retries=1, deadline_s=2.0))
        tuning_cache.configure_service(client=client)
        params = tuning_cache.lookup_or_tune("matmul", spec=TARGET, **SIG)
        assert params == expected           # degraded or served: correct
        assert inj.fired                    # the fault actually fired
        # standing faults keep degrading without ever raising
        assert tuning_cache.lookup_or_tune("matmul", spec=TARGET,
                                           **SIG) == expected


def test_chaos_delay_past_deadline_degrades():
    """A backend slower than the deadline is indistinguishable from a
    dead one: the dispatch answers from the local tiers in bounded
    time instead of stalling behind the service."""
    expected = local_params()
    tuning_cache.set_default_db(TuningDatabase())
    inj = FaultInjector([parse_fault("delay@server.request:delay=30")])
    with TuningServer(injector=inj) as srv:
        client = ServiceClient(srv.url, policy=fast_policy(
            retries=0, deadline_s=0.5, connect_timeout_s=0.3))
        tuning_cache.configure_service(client=client)
        t0 = time.monotonic()
        assert tuning_cache.lookup_or_tune("matmul", spec=TARGET,
                                           **SIG) == expected
        assert time.monotonic() - t0 < 5.0  # bounded, not 30s
        assert client.stats.degraded == 1


def test_service_skipped_for_explicit_db_and_model():
    client = ServiceClient("http://127.0.0.1:9", policy=fast_policy())
    tuning_cache.configure_service(client=client)
    params = tuning_cache.lookup_or_tune("matmul", spec=TARGET,
                                         db=TuningDatabase(), **SIG)
    assert params and client.stats.requests == 0


# ---------------------------------------------------------------------------
# generation-stamped invalidation
# ---------------------------------------------------------------------------


def test_generation_change_invalidates_frozen_tables(server):
    client = ServiceClient(server.url, policy=fast_policy())
    tuning_cache.configure_service(client=client)
    params = tuning_cache.lookup_or_tune("matmul", spec=TARGET, **SIG)
    assert params and client.generation == 0
    assert tuning_cache.freeze() > 0 and registry.is_frozen()
    local_gen = tuning_cache.get_default_db().generation
    # operator mutates the SHARED db: the server's generation moves
    server.db.invalidate()
    assert registry.is_frozen()             # not yet observed
    # ...and the next response's stamp thaws us through the hooks
    client.health()
    assert client.stats.generation_changes == 1
    assert not registry.is_frozen()
    assert tuning_cache.get_default_db().generation == local_gen + 1
    # dispatch still answers (through the live tiers)
    assert tuning_cache.lookup_or_tune("matmul", spec=TARGET,
                                       **SIG) == params


def test_env_var_configures_service(server, monkeypatch):
    monkeypatch.setenv(tuning_cache.ENV_SERVICE, server.url)
    tuning_cache._service_env_checked = False       # re-arm the lazy probe
    try:
        client = tuning_cache.service_client()
        assert client is not None and client.url == server.url
        assert tuning_cache.lookup_or_tune("matmul", spec=TARGET,
                                           **SIG) == local_params()
        assert client.stats.requests >= 1
    finally:
        tuning_cache.configure_service(None)


# ---------------------------------------------------------------------------
# multi-process: exactly one tune per cold key; crash mid-tune
# ---------------------------------------------------------------------------

_CLIENT_SCRIPT = """
import json, sys
from repro.tuning_cache.service.client import ClientPolicy, ServiceClient
c = ServiceClient(sys.argv[1],
                  policy=ClientPolicy(deadline_s=60, connect_timeout_s=50,
                                      retries=0))
r = c.resolve("matmul", {"m": 320, "n": 320, "k": 320}, target="tpu-v5e")
print(json.dumps(None if r is None else r["params"]))
"""


def test_multiprocess_cold_key_tunes_exactly_once(server):
    """≥4 client *processes* race the same cold key: the delay fault
    holds the single tune open long enough that every process arrives
    mid-flight, and the server still runs exactly one rank."""
    server.injector.add(parse_fault("delay@server.tune:delay=3.0,times=1"))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    procs = [subprocess.Popen([sys.executable, "-c", _CLIENT_SCRIPT,
                               server.url],
                              stdout=subprocess.PIPE, env=env, text=True)
             for _ in range(4)]
    outs = [p.communicate(timeout=120)[0].strip() for p in procs]
    assert all(p.returncode == 0 for p in procs)
    params = [json.loads(o) for o in outs]
    assert all(p is not None for p in params)
    assert all(p == params[0] for p in params)
    assert server.stats.tunes == 1          # the hard guarantee
    assert server.injector.hits("server.tune") == 1


def test_server_killed_mid_tune_client_degrades(tmp_path):
    """kill@server.tune crashes the server process inside the rank; the
    client degrades to None (and dispatch would fall through locally)
    while the server exits with the injected code."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.tuning_cache",
         "--db", str(tmp_path / "db"), "serve",
         "--fault", "kill@server.tune"],
        stdout=subprocess.PIPE, env=env, text=True)
    try:
        line = proc.stdout.readline()       # the flushed ready line
        assert "listening on" in line
        url = line.split("listening on ")[1].split()[0]
        client = ServiceClient(url, policy=fast_policy(
            retries=0, deadline_s=10.0, connect_timeout_s=8.0))
        assert client.resolve("matmul", SIG, target=TARGET) is None
        assert client.stats.degraded == 1
        client.close()
        assert proc.wait(timeout=30) == 86  # died exactly where injected
    finally:
        if proc.poll() is None:
            proc.kill()
        proc.stdout.close()


# ---------------------------------------------------------------------------
# launcher integration
# ---------------------------------------------------------------------------


def test_warm_tuning_db_reports_and_strict_exits(tmp_path, capsys):
    from repro.core.hw import TPU_V5E
    from repro.launch.serve import _warm_tuning_db
    rec = tuning_cache.TuningRecord(
        key=tuning_cache.make_key("matvec", spec=TPU_V5E, m=128, n=128,
                                  dtype="float32"),
        params={"bm": 64})
    path = tmp_path / "mix.jsonl"
    path.write_text(json.dumps(rec.to_dict()) + "\n"
                    + "corrupt line one\n" + '{"params": {}}\n')
    db = TuningDatabase()
    assert _warm_tuning_db(db, str(path)) == (1, 2)
    assert "2 corrupt lines skipped" in capsys.readouterr().out
    with pytest.raises(SystemExit):
        _warm_tuning_db(TuningDatabase(), str(path), strict=True)
    with pytest.raises(SystemExit):         # unreadable + strict: loud
        _warm_tuning_db(TuningDatabase(), str(tmp_path / "absent.jsonl"),
                        strict=True)
    assert _warm_tuning_db(TuningDatabase(),
                           str(tmp_path / "absent.jsonl")) == (0, 0)
