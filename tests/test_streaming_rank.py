"""Streaming cold rank: chunked enumeration + constraint pushdown
parity (DESIGN.md §14).

The chunked lazy path (`SearchSpace.iter_lattice` -> per-chunk
constraint mask -> running-argmin `rank_space` / streaming
`StaticPrunedSearch.shortlist`) must be **bit-identical** to the
materialized path for any chunk size, any worker count, and any
constraint set — including argmin ties, which both paths must break
toward the smallest flat lattice index.  Property-style: spaces are
generated from seeded rngs, and every registered kernel x shipped
target pair is swept.
"""
import itertools
import random

import numpy as np
import pytest

import repro.kernels  # noqa: F401  (registers dispatch problems)
from repro import tuning_cache
from repro.core.hw import resolve_target
from repro.core.predict import static_times_batch
from repro.core.search import (Constraint, ExhaustiveSearch, GeneticSearch,
                               RandomSearch, SearchSpace, StaticPrunedSearch)
from repro.core.target import use_target
from repro.kernels.megamatmul import mega_matmul_spec
from repro.tuning_cache import TuningDatabase, TuningProblem
from repro.tuning_cache.registry import _model_for, rank_space
from repro.tuning_cache.cli import SHIPPED_TARGETS


@pytest.fixture(autouse=True)
def _fresh_default_db():
    tuning_cache.set_default_db(TuningDatabase())
    yield
    tuning_cache.reset_default_db()


def _random_space(seed, with_constraints):
    rng = random.Random(seed)
    ndim = rng.randint(1, 4)
    axes = {}
    for d in range(ndim):
        n = rng.randint(1, 6)
        axes[f"a{d}"] = tuple(rng.sample(range(1, 64), n))
    cons = ()
    if with_constraints:
        # keep roughly half the lattice: parity must hold on the
        # filtered enumeration, not just the full product
        cons = (Constraint(lambda c: (c["a0"] % 2 == 0)
                           | (c["a0"] % 3 == 0), "mod"),)
    return SearchSpace(axes, constraints=cons)


def _chunk_sizes(n):
    return sorted({1, 2, 7, max(1, n // 3), n or 1, n + 13})


# ---------------------------------------------------------------------------
# iter_lattice vs enumerate_lattice / enumerate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("constrained", [False, True])
def test_iter_lattice_bitwise_matches_enumerate_lattice(seed, constrained):
    space = _random_space(seed, constrained)
    ref = space.enumerate_lattice()
    for chunk in _chunk_sizes(space.size):
        chunks = list(space.iter_lattice(chunk))
        idx = np.concatenate([c.indices for c in chunks], axis=1)
        off = np.concatenate([c.offsets for c in chunks])
        np.testing.assert_array_equal(idx, ref.indices)
        np.testing.assert_array_equal(off, ref.offsets)
        for k in space.names:
            np.testing.assert_array_equal(
                np.concatenate([c.columns[k] for c in chunks]),
                ref.columns[k])
        # every chunk respects the bound (pre-filter rows <= chunk)
        assert all(c.size <= chunk for c in chunks)


@pytest.mark.parametrize("seed", range(4))
def test_iter_lattice_rows_are_enumerate_order(seed):
    space = _random_space(seed, True)
    rows = [c.params_at(i)
            for c in space.iter_lattice(5) for i in range(c.size)]
    assert rows == space.enumerate()
    # offsets decode back to the same configs
    offs = [int(g) for c in space.iter_lattice(5) for g in c.offsets]
    assert [space.from_flat(g) for g in offs] == rows


def test_iter_lattice_rejects_bad_chunk():
    space = _random_space(0, False)
    with pytest.raises(ValueError):
        next(space.iter_lattice(0))


def test_satisfies_agrees_with_batch_mask():
    space = _random_space(3, True)
    lat = SearchSpace(space.axes).enumerate_lattice()   # unfiltered
    mask = space.feasible_mask(lat.columns, lat.size)
    for i in range(lat.size):
        assert space.satisfies(lat.params_at(i)) == bool(mask[i])


# ---------------------------------------------------------------------------
# streaming rank_space parity
# ---------------------------------------------------------------------------


def _toy_problem(space, cost_fn):
    """TuningProblem whose batch analyzer scores `cost_fn(columns)`."""
    class _Info:
        def __init__(self, cols):
            t = np.asarray(cost_fn(cols), dtype=np.float64)
            # static_times_batch array form: time = F @ rates with a
            # one-column F and unit rate, pipe/feasible neutral
            self.F = t.reshape(-1, 1)
            self.pipe = np.zeros(t.size, dtype=np.float64)
            self.feasible = np.ones(t.size, dtype=bool)

    class _Model:
        def times(self, F, pipe, feasible):  # pragma: no cover - unused
            raise NotImplementedError

    def batch(cols):
        return _Info(cols)

    def scalar(p):
        raise NotImplementedError("streaming tests never build scalars")

    return TuningProblem(space=space, static_info=scalar,
                         static_info_batch=batch)


class _UnitModel:
    """CostModel stand-in: time == F[:, 0] + pipe."""

    def time_batch(self, mixes=None, F=None):
        return np.asarray(F, dtype=np.float64)[:, 0]

    def fingerprint(self):
        return "unit@test"


def _rank(problem, **kw):
    return rank_space(problem, _UnitModel(), **kw)


@pytest.mark.parametrize("seed", range(6))
def test_streaming_rank_matches_single_chunk(seed):
    space = _random_space(seed, True)
    rng = np.random.default_rng(seed)
    w = {k: rng.uniform(0.1, 2.0) for k in space.names}
    prob = _toy_problem(space, lambda c: sum(
        w[k] * np.asarray(c[k], dtype=np.float64) for k in space.names))
    try:
        ref = _rank(prob, chunk_size=space.size + 1)   # one chunk: eager
    except ValueError:
        ref = None
    for chunk in _chunk_sizes(space.size):
        if ref is None:
            with pytest.raises(ValueError):
                _rank(prob, chunk_size=chunk)
        else:
            assert _rank(prob, chunk_size=chunk) == ref


def test_streaming_rank_tie_breaks_to_first_flat_index():
    # constant cost: every feasible row ties; the winner must be the
    # first feasible row in enumeration order, for every chunking
    space = SearchSpace({"a": (1, 2, 3, 4), "b": (1, 2, 3)},
                        constraints=(lambda c: c["a"] >= 2,))
    prob = _toy_problem(space, lambda c: np.zeros(len(c["a"])))
    want = {"a": 2, "b": 1}                  # flat index 3
    for chunk in (1, 2, 5, 100):
        p, t, n = _rank(prob, chunk_size=chunk)
        assert (p, t, n) == (want, 0.0, 9)


def test_streaming_rank_workers_bitwise_parity():
    space = _random_space(11, True)
    prob = _toy_problem(space, lambda c: np.asarray(
        c[space.names[0]], dtype=np.float64) * 1.7)
    ref = _rank(prob, chunk_size=space.size + 1)
    for workers in (2, 4):
        assert _rank(prob, chunk_size=3, workers=workers) == ref


def test_constraint_pushdown_never_scores_infeasible_rows():
    space = SearchSpace({"a": tuple(range(10)), "b": tuple(range(10))},
                        constraints=(lambda c: c["a"] != 3,))
    seen_rows = []

    def cost(cols):
        seen_rows.append(np.asarray(cols["a"]))
        return np.asarray(cols["a"], dtype=np.float64) + 1.0

    prob = _toy_problem(space, cost)
    _, _, scored = _rank(prob, chunk_size=7)
    seen = np.concatenate(seen_rows)
    assert scored == len(seen) == 90         # 10 rows filtered out
    assert not np.any(seen == 3)             # pushdown: never materialized


def test_all_infeasible_space_raises_both_paths():
    space = SearchSpace({"a": (1, 2, 3)},
                        constraints=(lambda c: c["a"] > 99,))
    prob = _toy_problem(space, lambda c: np.asarray(c["a"], float))
    with pytest.raises(ValueError):
        _rank(prob, chunk_size=2)            # streaming
    scalar_prob = TuningProblem(space=space, static_info=lambda p: None)
    with pytest.raises(ValueError):
        rank_space(scalar_prob, _UnitModel())   # scalar fallback


# ---------------------------------------------------------------------------
# every registered kernel x shipped target: chunked == eager
# ---------------------------------------------------------------------------

_KERNEL_SIGS = {
    "matmul": dict(m=512, n=256, k=1024, dtype="float32"),
    "matvec": dict(m=2048, n=1024, dtype="float32"),
    "atax": dict(m=1024, n=512, dtype="float32"),
    "bicg": dict(m=2048, n=2048, dtype="bfloat16"),
    "jacobi3d": dict(z=128, y=64, x=128, dtype="float32"),
    "flash_attention": dict(b=2, h=4, sq=1024, skv=1024, d=128,
                            causal=True, dtype="float32"),
    "stencil2d": dict(y=1024, x=512, dtype="float32"),
}


@pytest.mark.parametrize("target", SHIPPED_TARGETS)
@pytest.mark.parametrize("kernel_id", sorted(_KERNEL_SIGS))
def test_chunked_rank_bitwise_matches_eager_every_kernel_target(
        kernel_id, target):
    spec = resolve_target(target)
    with use_target(spec):
        prob = tuning_cache.get_problem(kernel_id, **_KERNEL_SIGS[kernel_id])
        model = _model_for(spec)
        eager = rank_space(prob, model, chunk_size=prob.space.size + 1)
        for chunk in (1, 7, max(1, prob.space.size // 2)):
            assert rank_space(prob, model, chunk_size=chunk) == eager
        assert rank_space(prob, model, chunk_size=5, workers=3) == eager


# ---------------------------------------------------------------------------
# streaming StaticPrunedSearch shortlist
# ---------------------------------------------------------------------------


def _mega_small():
    # 40 divides nothing in 192 = 2^6*3; unroll 3 only divides bk 24/48
    spec = mega_matmul_spec(blocks=(8, 16, 24, 32, 40, 48),
                            unrolls=(1, 2, 3), orders=("mnk", "kmn"),
                            schemes=("blocked",), accs=("f32",))
    return spec.problem(m=192, n=192, k=192, dtype="float32")


def test_mega_factory_space_shape_and_constraints():
    prob = _mega_small()
    space = prob.space
    assert space.size == 6 ** 3 * 3 * 2      # full lattice
    lat = space.enumerate_lattice()
    assert 0 < lat.size < space.size         # constraints filter some
    # scalar satisfies() agrees with the batch mask row-by-row
    for i in range(0, lat.size, max(1, lat.size // 37)):
        assert space.satisfies(lat.params_at(i))
    # mega registration is opt-in: the registry must not have grown
    assert "mega_matmul" not in tuning_cache.registered()


def test_streaming_shortlist_bitwise_matches_eager():
    prob = _mega_small()
    spec = resolve_target("tpu-v5e")
    model = _model_for(spec)

    def cost(p):
        with use_target(spec):
            return prob.static_info(p).static_time(model)

    def cost_cols(cols):
        with use_target(spec):
            b = prob.static_info_batch(cols)
        return static_times_batch(None, model, F=b.F, pipe=b.pipe,
                                  feasible=b.feasible)

    for keep in (dict(keep_n=16), dict(keep_frac=0.05)):
        eager = StaticPrunedSearch(cost, static_cost_batch=lambda pts:
                                   cost_cols({k: np.asarray([p[k] for p in pts])
                                              for k in prob.space.names}),
                                   **keep).shortlist(prob.space)
        streaming = StaticPrunedSearch(cost, static_cost_cols=cost_cols,
                                       chunk_size=97,
                                       **keep).shortlist(prob.space)
        assert streaming == eager


def test_streaming_shortlist_all_infeasible_raises():
    space = SearchSpace({"a": tuple(range(50))},
                        constraints=(lambda c: c["a"] > 99,))
    s = StaticPrunedSearch(lambda p: 0.0, keep_n=4, chunk_size=8,
                           static_cost_cols=lambda c: np.asarray(
                               c["a"], dtype=np.float64))
    with pytest.raises(ValueError):
        s.shortlist(space)


# ---------------------------------------------------------------------------
# satellite behaviours on the point-op / strategy layer
# ---------------------------------------------------------------------------


def test_index_of_duplicate_axis_values_uses_first_index():
    space = SearchSpace({"a": (8, 16, 8, 32), "b": ("x", "y")})
    assert space.index_of({"a": 8, "b": "y"}) == (0, 1)
    assert space.index_of({"a": 32, "b": "x"}) == (3, 0)
    with pytest.raises(ValueError):
        space.index_of({"a": 99, "b": "x"})


def test_neighbors_respects_constraints():
    space = SearchSpace({"a": tuple(range(10))},
                        constraints=(lambda c: c["a"] % 2 == 0,))
    rng = random.Random(0)
    p = {"a": 4}
    for _ in range(50):
        q = space.neighbors(p, rng)
        assert space.satisfies(q)


def test_exhaustive_budget_is_lazy_on_astronomical_space():
    # 40^12 ~ 1.7e19 points: a materializing implementation would die
    space = SearchSpace({f"a{d}": tuple(range(40)) for d in range(12)})
    res = ExhaustiveSearch().minimize(
        lambda p: sum(p.values()), space, budget=50)
    assert res.evaluations == 50
    assert res.candidates_considered == 50


def test_sample_raises_when_constraints_unsatisfiable():
    space = SearchSpace({"a": (1, 3, 5)},
                        constraints=(lambda c: c["a"] % 2 == 0,))
    with pytest.raises(ValueError):
        space.sample(random.Random(0), max_tries=25)


def test_random_search_dedup_distinguishes_value_types():
    # keys are axis-index tuples now: 1 and "1" are distinct configs,
    # a str()-keyed dedup would collapse them and underfill the budget
    space = SearchSpace({"a": (1, "1")})
    seen = []

    def obj(p):
        seen.append(p["a"])
        return 0.0

    res = RandomSearch(seed=0).minimize(obj, space, budget=2)
    assert res.evaluations == 2
    assert sorted(map(str, seen)) == ["1", "1"]
    assert {type(v) for v in seen} == {int, str}


def test_genetic_search_runs_under_constraints():
    space = SearchSpace({"a": tuple(range(16)), "b": tuple(range(16))},
                        constraints=(lambda c: (c["a"] + c["b"]) % 2 == 0,))
    res = GeneticSearch(seed=1).minimize(
        lambda p: p["a"] + p["b"], space, budget=60)
    assert res.best_value == 0.0             # a=0,b=0 is feasible
    assert space.satisfies(res.best_params)
