"""Pipeline cost-model tier: ISA tables, scoreboard simulator, reranker.

Hand-computed simulator cases use a synthetic `IsaTable` so every
expected cycle count is derivable on paper; integration cases go
through the real per-family tables and the registry's two-stage rank
(DESIGN.md §16).
"""
import json
import math

import numpy as np
import pytest

import repro.kernels  # noqa: F401  (registers every @tuned_kernel)
import repro.tuning_cache as tc
from repro.core.hw import resolve_target
from repro.core.isa import CLASSES, IsaOp, IsaTable, isa_table_for
from repro.core.pipeline import (InstructionStream, StreamOp, as_stream,
                                 simulate, stream_from_hlo,
                                 synthesize_stream)
from repro.core.predict import spearman
from repro.core.target import use_target
from repro.tuning_cache import (TuningDatabase, get_problem, lookup_or_tune,
                                rank_space, registry)
from repro.tuning_cache.cli import SHIPPED_TARGETS

TPU = resolve_target("tpu-v5e")
MM_SIG = dict(m=256, n=256, k=256, dtype="float32")


# ---------------------------------------------------------------------------
# ISA tables
# ---------------------------------------------------------------------------

ALL_TARGETS = SHIPPED_TARGETS + ("tpu-v4",)


@pytest.mark.parametrize("target", ALL_TARGETS)
def test_isa_table_complete(target):
    table = isa_table_for(resolve_target(target))
    assert table.clock_hz > 0
    assert table.barrier_slots >= 1
    assert table.provenance
    for cls in CLASSES:
        row = table.op(cls)
        # never silently defaulted: every class priced with positive
        # numbers and a documented provenance
        assert row.work > 0, (target, cls)
        assert row.issue > 0, (target, cls)
        assert row.latency > 0, (target, cls)
        assert row.provenance, (target, cls)


def test_isa_fingerprints_distinct_and_stable():
    fps = [isa_table_for(resolve_target(t)).fingerprint()
           for t in ALL_TARGETS]
    assert len(set(fps)) == len(fps)
    for t, fp in zip(ALL_TARGETS, fps):
        assert isa_table_for(resolve_target(t)).fingerprint() == fp


def test_isa_unknown_class_raises():
    with pytest.raises(KeyError, match="prices no class"):
        isa_table_for(TPU).op("tensor-cores")


def test_model_fingerprints_separate_tiers():
    for target in ("tpu-v5e", "kepler-k20"):
        spec = resolve_target(target)
        eq6 = registry._model_for(spec, "eq6").fingerprint()
        pipe = registry._model_for(spec, "pipeline").fingerprint()
        assert eq6 != pipe
        assert pipe.startswith("pipeline-")


# ---------------------------------------------------------------------------
# scoreboard simulator (hand-computed cases, synthetic table)
# ---------------------------------------------------------------------------


def _table(rows, *, barrier_slots=4):
    ops = {cls: IsaOp(cls=cls, pipe=pipe, work=work, issue=issue,
                      latency=lat, dual_issue=dual, yields=yields,
                      barrier=barrier, provenance="test")
           for cls, (pipe, work, issue, lat, dual, yields, barrier)
           in rows.items()}
    return IsaTable(family="test", clock_hz=1.0e9,
                    barrier_slots=barrier_slots, ops=ops)


def test_simulate_dependence_stall():
    # mxu: pipe A, 1 cy issue, 10 cy latency, does NOT yield;
    # vpu: pipe B, 1 cy issue, 2 cy latency.
    t = _table({"mxu": ("A", 1.0, 1.0, 10.0, False, False, ""),
                "vpu": ("B", 1.0, 1.0, 2.0, False, True, "")})
    dep = InstructionStream((StreamOp("mxu", 4.0),
                            StreamOp("vpu", 8.0, dep=0)), concurrency=2.0)
    res = simulate(dep, t)
    # producer result-ready = 3*1 + 10 = 13; consumer could issue at 4
    # -> 9 stall cycles on pipe B, charged hard (producer doesn't
    # yield): busy_max(8) + 9 = 17 beats t_end/c = 22/2.
    assert res.cycles == pytest.approx(17.0)
    assert res.stalls == {"B": pytest.approx(9.0)}
    assert res.limiter == "B"
    free = simulate(InstructionStream((StreamOp("mxu", 4.0),
                                       StreamOp("vpu", 8.0)),
                                      concurrency=2.0), t)
    assert free.cycles == pytest.approx(8.0)
    assert free.stalls == {}


def test_simulate_dual_issue_pairing():
    paired = _table({"ctrl": ("S", 1.0, 1.0, 1.0, True, False, ""),
                     "reg": ("B", 1.0, 1.0, 1.0, True, False, "")})
    serial = _table({"ctrl": ("S", 1.0, 1.0, 1.0, True, False, ""),
                     "reg": ("B", 1.0, 1.0, 1.0, False, False, "")})
    stream = InstructionStream((StreamOp("ctrl", 4.0), StreamOp("reg", 4.0)))
    # both dual-issue on different pipes: the reg segment co-issues at
    # the ctrl segment's start instead of after it
    assert simulate(stream, paired).cycles == pytest.approx(4.0)
    assert simulate(stream, serial).cycles == pytest.approx(8.0)


def test_simulate_memory_barrier_slots():
    rows = {"hbm": ("M", 1.0, 1.0, 100.0, False, True, "wr")}
    stream = InstructionStream(tuple(StreamOp("hbm", 1.0)
                                     for _ in range(3)))
    # 2 slots: the third load waits for the oldest outstanding result
    # (cycle 100), landing its own at 200
    tight = simulate(stream, _table(rows, barrier_slots=2))
    roomy = simulate(stream, _table(rows, barrier_slots=8))
    assert tight.cycles == pytest.approx(200.0)
    assert roomy.cycles == pytest.approx(102.0)
    assert tight.limiter == "latency"


def test_simulate_occupancy_interleave_and_saturation():
    t = _table({"vpu": ("B", 1.0, 1.0, 20.0, False, True, "")})
    stream = InstructionStream((StreamOp("vpu", 10.0),))
    # single context: the trailing result latency is exposed
    assert simulate(stream, t, concurrency=1).cycles == pytest.approx(29.0)
    # 4 contexts hide it: issue-bound at 10 cycles
    assert simulate(stream, t, concurrency=4,
                    saturation=4).cycles == pytest.approx(10.0)
    # below saturation, issue bandwidth stretches by c/sat (Eq. 2)
    assert simulate(stream, t, concurrency=4,
                    saturation=8).cycles == pytest.approx(20.0)
    assert simulate(stream, t, concurrency=8,
                    saturation=8).cycles == pytest.approx(10.0)


def test_simulate_empty_stream():
    res = simulate(InstructionStream(()), isa_table_for(TPU))
    assert res.cycles == 0.0 and res.limiter == "empty"


def test_simulate_iterations_scale():
    t = _table({"vpu": ("B", 1.0, 1.0, 1.0, False, True, "")})
    one = simulate(InstructionStream((StreamOp("vpu", 8.0),)), t)
    many = simulate(InstructionStream((StreamOp("vpu", 8.0),),
                                      iterations=5.0), t)
    assert many.cycles == pytest.approx(5.0 * one.cycles)


# ---------------------------------------------------------------------------
# stream extraction
# ---------------------------------------------------------------------------


def test_synthesize_stream_deterministic_order_and_deps():
    s = synthesize_stream({"mxu": 5.0, "hbm": 3.0, "ctrl": 1.0,
                           "vpu": 0.0})
    assert [op.cls for op in s.ops] == ["hbm", "mxu", "ctrl"]
    assert s.ops[0].dep is None
    assert s.ops[1].dep == 0          # mxu consumes the hbm stage
    assert s.ops[2].dep is None


def test_as_stream_validates_rows():
    with pytest.raises(ValueError, match="unknown instruction class"):
        as_stream([("simd", 1.0)])
    with pytest.raises(ValueError, match="not an earlier row"):
        as_stream([("mxu", 1.0, 0)])
    s = as_stream([("hbm", 2.0), ("mxu", 4.0, 0)])
    assert s.ops[1].dep == 0 and s.iterations == 1.0


def test_matmul_schedule_hook():
    from repro.kernels.matmul import _matmul_schedule
    p = {"bm": 128, "bn": 128, "bk": 128}
    rows = _matmul_schedule(p, m=512, n=512, k=512)
    model = registry._model_for(TPU, "pipeline")
    with use_target(TPU):
        problem = get_problem("matmul", m=512, n=512, k=512,
                              dtype="float32")
        assert problem.schedule is not None
        info = problem.static_info(p)
    res = model.result_of(info, schedule=rows)
    assert res is not None
    assert math.isfinite(res.seconds) and res.seconds > 0
    # the declared stream's contraction depends on the staged tiles
    stream = as_stream(rows, info)
    assert stream.iterations > 1 and stream.ops[2].dep == 1


_WHILE_HLO = """\
HloModule synthetic

%cond (p.0: (s32[], f32[128])) -> pred[] {
  %p.0 = (s32[], f32[128]) parameter(0)
  %iv = s32[] get-tuple-element(%p.0), index=0
  %limit = s32[] constant(16)
  %junk = s32[] constant(999)
  ROOT %lt = pred[] compare(%iv, %limit), direction=LT
}

%body (p.1: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p.1 = (s32[], f32[128]) parameter(0)
  %iv.1 = s32[] get-tuple-element(%p.1), index=0
  %one = s32[] constant(1)
  %next = s32[] add(%iv.1, %one)
  %x = f32[128] get-tuple-element(%p.1), index=1
  %t = f32[128] tanh(%x)
  ROOT %tup = (s32[], f32[128]) tuple(%next, %t)
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %a = f32[128] parameter(0)
  %init = s32[] constant(0)
  %tup.0 = (s32[], f32[128]) tuple(%init, %a)
  %w = (s32[], f32[128]) while(%tup.0), condition=%cond, body=%body
  ROOT %out = f32[128] get-tuple-element(%w), index=1
}
"""


def test_stream_from_hlo_trip_scaled():
    # the exact ROOT-compare bound (16) scales the body, not the
    # distractor constant(999) the old max-constant heuristic grabbed
    stream = stream_from_hlo(_WHILE_HLO)
    trans = sum(op.units for op in stream.ops if op.cls == "trans")
    assert trans == pytest.approx(16 * 128)


# ---------------------------------------------------------------------------
# two-stage rank: determinism + cache separation + frozen coherence
# ---------------------------------------------------------------------------


def test_rerank_deterministic_across_chunks_and_workers():
    model = registry._model_for(TPU, "pipeline")
    with use_target(TPU):
        problem = get_problem("matmul", m=512, n=512, k=512,
                              dtype="float32")
        results = [rank_space(problem, model, chunk_size=cs, workers=w)
                   for cs in (None, 7, 64) for w in (None, 4)]
    first = results[0]
    assert first[2] > 0
    for other in results[1:]:
        assert other == first


def test_rerank_scalar_batch_parity():
    model = registry._model_for(TPU, "pipeline")
    with use_target(TPU):
        problem = get_problem("matmul", **MM_SIG)
        got = rank_space(problem, model)
        scalar = tc.TuningProblem(space=problem.space,
                                  static_info=problem.static_info,
                                  schedule=problem.schedule)
        got_scalar = rank_space(scalar, model)
    assert got_scalar[0] == got[0]
    assert got_scalar[1] == pytest.approx(got[1])


def test_eq6_path_unchanged_by_pipeline_import():
    # the plain model must still route through the one-stage SoA path
    model = registry._model_for(TPU, "eq6")
    with use_target(TPU):
        problem = get_problem("matmul", **MM_SIG)
        a = rank_space(problem, model)
        b = rank_space(problem, model, chunk_size=11, workers=3)
    assert a == b


def test_cache_keys_separate_model_kinds():
    mem = TuningDatabase()
    p_eq6 = lookup_or_tune("matmul", db=mem, spec=TPU, **MM_SIG)
    p_pipe = lookup_or_tune("matmul", db=mem, spec=TPU, model="pipeline",
                            **MM_SIG)
    assert len(mem) == 2          # distinct records, never a collision
    fps = {json.loads(r.key.signature).get("model") for r in mem.records()}
    assert len(fps) == 2
    # repeat lookups are cache hits onto their own tier's record
    assert lookup_or_tune("matmul", db=mem, spec=TPU, **MM_SIG) == p_eq6
    assert lookup_or_tune("matmul", db=mem, spec=TPU, model="pipeline",
                          **MM_SIG) == p_pipe
    assert len(mem) == 2


def test_unknown_model_kind_rejected():
    with pytest.raises(ValueError, match="unknown tuning model"):
        lookup_or_tune("matmul", db=TuningDatabase(), spec=TPU,
                       model="oracle", **MM_SIG)


def test_default_model_switch_thaws_and_rekeys():
    tc.clear_dispatch_memo()
    try:
        lookup_or_tune("matmul", spec=TPU, **MM_SIG)
        tc.freeze()
        assert tc.is_frozen()
        # switching the process default invalidates frozen answers
        assert tc.set_default_model("pipeline") == "pipeline"
        assert not tc.is_frozen()
        lookup_or_tune("matmul", spec=TPU, **MM_SIG)
        kinds = {k[-1] for k in registry.dispatch_memo_keys()
                 if k[0] == "matmul"}
        assert "pipeline" in kinds
    finally:
        tc.set_default_model(None)
        tc.thaw()
        tc.clear_dispatch_memo()


def test_env_selects_default_kind(monkeypatch):
    monkeypatch.setenv(tc.ENV_MODEL, "pipeline")
    try:
        tc.set_default_model(None)      # drop the cached read
        assert tc.default_model_kind() == "pipeline"
    finally:
        monkeypatch.delenv(tc.ENV_MODEL)
        tc.set_default_model(None)
        assert tc.default_model_kind() == "eq6"


def test_kernel_declared_kind(tmp_path):
    from repro.kernels.api import divisors, tuned_kernel, unregister

    @tuned_kernel("pipe_toy", space={"b": divisors("x", (8, 16, 32))},
                  signature=lambda u, **_: dict(x=u.shape[0]),
                  static_info=lambda p, *, x: dict(
                      in_blocks=[(p["b"], 128)], out_blocks=[(p["b"], 128)],
                      in_dtypes=["float32"], out_dtypes=["float32"],
                      flops_per_step=np.asarray(p["b"],
                                                dtype=np.float64) * 128.0,
                      grid_steps=x // np.maximum(np.asarray(p["b"]), 1)),
                  model="pipeline")
    def pipe_toy(u, *, b=8):
        return u

    try:
        mem = TuningDatabase()
        lookup_or_tune("pipe_toy", db=mem, spec=TPU, x=64)
        (rec,) = mem.records()
        fp = json.loads(rec.key.signature)["model"]
        assert fp.startswith("pipeline-")
    finally:
        unregister("pipe_toy")


def test_declared_kind_validated():
    from repro.kernels.api import divisors, tuned_kernel
    with pytest.raises(ValueError, match="model must be one of"):
        @tuned_kernel("bad_kind", space={"b": divisors("x", (8,))},
                      signature=lambda u, **_: dict(x=u.shape[0]),
                      static_info=lambda p, *, x: {},
                      model="exact")
        def bad(u, *, b=8):
            return u


# ---------------------------------------------------------------------------
# spearman (the benchmark's scoring primitive)
# ---------------------------------------------------------------------------


def test_spearman_constant_vector_is_zero():
    assert spearman([1.0, 1.0, 1.0], [1.0, 2.0, 3.0]) == 0.0
    assert spearman([3, 1, 2], [7, 7, 7]) == 0.0
    assert spearman([5, 5], [5, 5]) == 0.0


def test_spearman_ties_average_ranks():
    # scipy.stats.spearmanr([1,2,2,3],[1,2,3,4]) == 0.9486832980505138
    assert spearman([1, 2, 2, 3], [1, 2, 3, 4]) == pytest.approx(
        0.9486832980505138)
    assert spearman([1, 2, 2, 3], [10, 20, 20, 30]) == pytest.approx(1.0)
    assert spearman([1, 2, 2, 3], [30, 20, 20, 10]) == pytest.approx(-1.0)
