"""Hardware-target registry tests (ISSUE 3 acceptance).

Covers: name resolution, the process-default stack (explicit >
REPRO_TUNING_TARGET > autodetect > v5e), `use_target` scoping incl.
exception safety, per-target isolation of cache keys / dispatch-memo
entries / winning params, lazy warming of the shipped per-target
databases, and the end-to-end acceptance criterion — an unmodified
program dispatches with the chip picked by the environment variable,
served entirely from the shipped database.
"""
import json
import os
import subprocess
import sys

import pytest

from repro import tuning_cache
from repro.core import (TPU_TABLE, TPU_V4, TPU_V5E, TPU_V5P, TPU_V6E,
                        TpuSpec, default_target, resolve_target,
                        set_default_target, use_target)
from repro.core import target as target_mod
from repro.tuning_cache import TuningDatabase, fingerprint_spec
from repro.tuning_cache import registry as registry_mod
from repro.tuning_cache.cli import SHIPPED_TARGETS
from repro.tuning_cache.cli import main as cli_main

import repro.kernels  # noqa: F401  (registers dispatch problems)


@pytest.fixture(autouse=True)
def _fresh_target_and_db(monkeypatch):
    """Isolate each test from ambient target/env/database state."""
    monkeypatch.delenv(target_mod.ENV_TARGET, raising=False)
    set_default_target(None)
    tuning_cache.set_default_db(TuningDatabase())
    yield
    set_default_target(None)
    tuning_cache.reset_default_db()


# ---------------------------------------------------------------------------
# Resolution + the TPU table
# ---------------------------------------------------------------------------


def test_resolve_target_aliases():
    assert resolve_target("tpu-v5p") is TPU_V5P
    assert resolve_target("v5p") is TPU_V5P
    assert resolve_target("TPU_V4") is TPU_V4
    assert resolve_target("TPU v6e") is TPU_V6E
    # jax device_kind spellings
    assert resolve_target("TPU v5 lite") is TPU_V5E
    assert resolve_target("TPU v6 lite") is TPU_V6E
    assert resolve_target("TPU v5") is TPU_V5P    # v5p's device_kind
    assert resolve_target("TPU v4") is TPU_V4
    # spec passthrough
    custom = TpuSpec(hbm_bw=1.0)
    assert resolve_target(custom) is custom
    with pytest.raises(KeyError):
        resolve_target("tpu-v99")


def test_tpu_table_is_per_chip_distinct():
    canonical = {k: v for k, v in TPU_TABLE.items() if k.startswith("tpu-")}
    assert set(canonical) == {"tpu-v4", "tpu-v5e", "tpu-v5p", "tpu-v6e"}
    fps = {fingerprint_spec(s) for s in canonical.values()}
    assert len(fps) == 4                      # no two chips collide
    # ICI topology drives links-per-chip: 3D torus chips have 6.
    assert TPU_V4.ici_links == 6 and TPU_V5P.ici_links == 6
    assert TPU_V5E.ici_links == 4 and TPU_V6E.ici_links == 4


# ---------------------------------------------------------------------------
# Default-target stack
# ---------------------------------------------------------------------------


def test_default_target_fallback_is_v5e():
    # CPU test box: no TPU to detect, no env, no explicit pin.
    assert default_target() is TPU_V5E


def test_env_override(monkeypatch):
    monkeypatch.setenv(target_mod.ENV_TARGET, "tpu-v5p")
    assert default_target() is TPU_V5P
    # explicit set shadows the environment ...
    set_default_target("tpu-v6e")
    assert default_target() is TPU_V6E
    # ... and clearing it falls back to the env again
    set_default_target(None)
    assert default_target() is TPU_V5P


def test_use_target_restores_prior_default():
    set_default_target("tpu-v4")
    with use_target("tpu-v5p") as spec:
        assert spec is TPU_V5P
        assert default_target() is TPU_V5P
        with use_target(TPU_V6E):             # nests
            assert default_target() is TPU_V6E
        assert default_target() is TPU_V5P
    assert default_target() is TPU_V4


def test_use_target_restores_on_exception():
    with pytest.raises(RuntimeError):
        with use_target("tpu-v5p"):
            raise RuntimeError("boom")
    assert default_target() is TPU_V5E


def test_use_target_is_thread_local():
    """`use_target` scopes are context-local: one thread pinning v5p
    around an analysis can never leak v5p into another thread."""
    import threading
    seen, ready, release = {}, threading.Barrier(2), threading.Barrier(2)

    def worker(name, target):
        with use_target(target):
            ready.wait(timeout=10)       # both scopes active at once
            seen[name] = default_target()
            release.wait(timeout=10)

    threads = [threading.Thread(target=worker, args=("a", "tpu-v5p")),
               threading.Thread(target=worker, args=("b", "tpu-v6e"))]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=20)
    assert seen == {"a": TPU_V5P, "b": TPU_V6E}
    assert default_target() is TPU_V5E   # main thread never saw either


# ---------------------------------------------------------------------------
# Per-target isolation of the tuning stack
# ---------------------------------------------------------------------------

_SIG = dict(m=512, n=512, k=512, dtype="float32")


def test_two_targets_two_cache_keys():
    """Same kernel/signature under two targets -> two database records
    with distinct spec fingerprints."""
    db = TuningDatabase()
    tuning_cache.lookup_or_tune("matmul", db=db, spec=TPU_V5E, **_SIG)
    tuning_cache.lookup_or_tune("matmul", db=db, spec=TPU_V5P, **_SIG)
    recs = list(db.records())
    assert len(recs) == 2
    assert len({r.key.spec_fingerprint for r in recs}) == 2
    assert db.stats.tunes == 2                # no cross-target hit


def test_two_targets_two_dispatch_memo_entries():
    """The warm-dispatch memo keys on the spec fingerprint: switching
    targets can never serve the other chip's memoized params."""
    tuning_cache.clear_dispatch_memo()
    with use_target("tpu-v5e"):
        tuning_cache.lookup_or_tune("matmul", **_SIG)
    with use_target("tpu-v5p"):
        tuning_cache.lookup_or_tune("matmul", **_SIG)
    fps = {k[2] for k in registry_mod.dispatch_memo_keys()}
    assert fingerprint_spec(TPU_V5E) in fps
    assert fingerprint_spec(TPU_V5P) in fps


def test_winning_params_differ_where_budgets_differ():
    """atax 2048x2048 f32: bm=1024 tiles fit v5p's VMEM budget but not
    v5e's, so the statically-ranked winner is chip-specific (the
    paper's Table-I observation transplanted to TPU)."""
    sig = dict(m=2048, n=2048, dtype="float32")
    db = TuningDatabase()
    p_v5e = tuning_cache.lookup_or_tune("atax", db=db, spec=TPU_V5E, **sig)
    p_v5p = tuning_cache.lookup_or_tune("atax", db=db, spec=TPU_V5P, **sig)
    assert p_v5e != p_v5p


def test_kernel_tuner_pinned_to_its_spec():
    """A KernelTuner built for one chip keeps analyzing for that chip
    even when the ambient default changes mid-life."""
    from repro.kernels import make_tunable_matmul
    from repro.core import KernelTuner
    tuner = KernelTuner(make_tunable_matmul(512, 512, 512), spec=TPU_V5P,
                        db=None)
    with use_target("tpu-v5e"):
        info = tuner._info(tuner._mid_params())
    # v5p occupancy: budget is 32 MiB, so the mid-config ratio must be
    # computed against v5p's budget, not ambient v5e's 16 MiB.
    assert info.occupancy.vmem_ratio == pytest.approx(
        info.occupancy.vmem_bytes / TPU_V5P.vmem_bytes)


# ---------------------------------------------------------------------------
# Shipped per-target databases
# ---------------------------------------------------------------------------


def test_warm_pretuned_is_lazy_and_per_target():
    db = tuning_cache.get_default_db()
    sig = dict(m=1024, n=1024, k=1024, dtype="float32")
    with use_target("tpu-v5e"):
        tuning_cache.lookup_or_tune("matmul", **sig)
    assert "tpu-v5e" in db.warmed_targets
    assert "tpu-v5p" not in db.warmed_targets   # other chips stay cold
    n0 = len(db)
    with use_target("tpu-v5p"):
        tuning_cache.lookup_or_tune("matmul", **sig)
    assert "tpu-v5p" in db.warmed_targets
    assert len(db) > n0                      # v5p records folded in
    assert db.stats.tunes == 0               # served from the shipped dbs


def test_pretune_verify_all_targets(tmp_path):
    """Every shipped pretuned JSONL must be regenerable bit-for-bit."""
    assert cli_main(["--db", str(tmp_path / "db"), "pretune",
                     "--verify", "--all-targets"]) == 0


def test_pretune_verify_detects_tampering(tmp_path):
    shipped = tuning_cache.pretuned_path("tpu-v5e")
    tampered = tmp_path / "tpu_v5e.jsonl"
    lines = open(shipped).read().splitlines()
    rec = json.loads(lines[0])
    rec["params"] = {k: 8 for k in rec["params"]}
    tampered.write_text("\n".join([json.dumps(rec, sort_keys=True)]
                                  + lines[1:]) + "\n")
    with pytest.raises(SystemExit):
        cli_main(["--db", str(tmp_path / "db"), "pretune", "--verify",
                  "--target", "tpu-v5e", "--out", str(tampered)])


# ---------------------------------------------------------------------------
# Acceptance criterion: env-selected target, shipped-db hit, zero tunes
# ---------------------------------------------------------------------------

_ACCEPTANCE_PROG = r"""
import json, sys
import repro.kernels
from repro import tuning_cache
from repro.core import default_target
from repro.core.predict import default_tpu_model
from repro.tuning_cache import fingerprint_spec, make_key
from repro.tuning_cache.registry import normalize_signature

sig = dict(m=1024, n=1024, k=1024, dtype="float32")
params = tuning_cache.lookup_or_tune("matmul", **sig)
db = tuning_cache.get_default_db()
spec = default_target()
key = make_key("matmul", spec=spec,
               model_name=default_tpu_model(spec, mode="max").fingerprint(),
               **normalize_signature("matmul", sig))
print(json.dumps({"target": spec.name, "params": params,
                  "digest": key.digest, "tunes": db.stats.tunes}))
"""


def _run_acceptance(target_name):
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH",
                                                              ""))
    env["REPRO_TUNING_TARGET"] = target_name
    env.pop("REPRO_TUNING_CACHE_DIR", None)
    out = subprocess.run([sys.executable, "-c", _ACCEPTANCE_PROG],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_acceptance_env_target_dispatches_from_shipped_db():
    """`REPRO_TUNING_TARGET=tpu-v5p python ...` dispatches matmul with
    v5p-ranked params straight from the shipped v5p database (zero
    model evaluations), the same program under tpu-v5e returns the v5e
    ranking, and the two runs resolve different cache keys."""
    a = _run_acceptance("tpu-v5p")
    b = _run_acceptance("tpu-v5e")
    assert a["target"] == "tpu-v5p" and b["target"] == "tpu-v5e"
    assert a["tunes"] == 0 and b["tunes"] == 0   # pure shipped-db hits
    assert a["digest"] != b["digest"]            # distinct cache keys
    for name, run in (("tpu_v5p", a), ("tpu_v5e", b)):
        path = os.path.join(tuning_cache.pretuned_dir(), f"{name}.jsonl")
        shipped = {json.loads(l)["key"]["signature"]: json.loads(l)["params"]
                   for l in open(path)}
        match = [p for s, p in shipped.items()
                 if '"k":1024,"m":1024' in s and '"n":1024' in s
                 and "float32" in s]
        assert run["params"] in match
