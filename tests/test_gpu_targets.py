"""Backend-polymorphic target tests (ISSUE 5 acceptance).

Covers: GPU name resolution through the unified target table, ChipSpec
fingerprints across families, bitwise scalar/batch parity of the CUDA
occupancy equations, `lookup_or_tune` under a `GpuSpec` returning
Table-VII-consistent params with zero program runs, cache-key /
dispatch-memo isolation between a GPU and a TPU target, the per-GPU
shipped pretuned databases (`pretune --verify` bit-identical), and the
non-finite ``predicted_s`` JSON round-trip the CUDA path exercises
organically (all-infeasible spaces rank to +inf).
"""
import json
import math

import pytest

from repro import tuning_cache
from repro.core import (FERMI_M2050, GPU_TABLE, KEPLER_K20, MAXWELL_M40,
                        TPU_V5E, GpuSpec, TpuSpec, default_target,
                        resolve_target, set_default_target,
                        suggest_cuda_params, use_target)
from repro.core.hw import ChipSpec
from repro.core.occupancy import cuda_occupancy, cuda_occupancy_batch
from repro.core.predict import default_cuda_model, default_tpu_model
from repro.tuning_cache import TuningDatabase, fingerprint_spec
from repro.tuning_cache import registry as registry_mod
from repro.tuning_cache.cli import SHIPPED_TARGETS
from repro.tuning_cache.cli import main as cli_main

import repro.kernels  # noqa: F401  (registers dispatch problems)
from repro.kernels.api import get_spec


@pytest.fixture(autouse=True)
def _fresh_target_and_db():
    set_default_target(None)
    tuning_cache.set_default_db(TuningDatabase())
    yield
    set_default_target(None)
    tuning_cache.reset_default_db()


# ---------------------------------------------------------------------------
# Resolution + the unified table
# ---------------------------------------------------------------------------


def test_resolve_gpu_aliases():
    assert resolve_target("kepler_k20") is KEPLER_K20
    assert resolve_target("kepler-k20") is KEPLER_K20
    assert resolve_target("k20") is KEPLER_K20
    assert resolve_target("kepler") is KEPLER_K20
    assert resolve_target("fermi_m2050") is FERMI_M2050
    assert resolve_target("MAXWELL_M40") is MAXWELL_M40
    # spec passthrough, both families
    assert resolve_target(KEPLER_K20) is KEPLER_K20
    assert resolve_target(TPU_V5E) is TPU_V5E
    with pytest.raises(KeyError):
        resolve_target("pascal_p100")


def test_chipspec_protocol_and_fingerprints():
    assert isinstance(KEPLER_K20, ChipSpec)
    assert isinstance(TPU_V5E, ChipSpec)
    fps = {fingerprint_spec(s) for s in
           (FERMI_M2050, KEPLER_K20, MAXWELL_M40, TPU_V5E)}
    assert len(fps) == 4               # no cross-family collision
    assert fingerprint_spec(KEPLER_K20).startswith("k20@")


def test_gpu_names_work_in_target_stack():
    set_default_target("kepler_k20")
    assert default_target() is KEPLER_K20
    set_default_target(None)
    with use_target("maxwell_m40") as spec:
        assert spec is MAXWELL_M40
        assert default_target() is MAXWELL_M40
    assert default_target() is TPU_V5E


def test_tpu_layers_reject_gpu_specs():
    from repro.core.occupancy import tpu_occupancy
    with pytest.raises(TypeError, match="cuda"):
        tpu_occupancy([1024], [1024], 1e6, spec=KEPLER_K20)
    with pytest.raises(TypeError):
        default_tpu_model(KEPLER_K20)
    with pytest.raises(TypeError):
        default_cuda_model(TPU_V5E)


# ---------------------------------------------------------------------------
# Scalar / batch parity of the faithful equations
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gpu_name", ["m2050", "k20", "m40"])
def test_cuda_occupancy_batch_bitwise_parity(gpu_name):
    gpu = GPU_TABLE[gpu_name]
    cases = [(t, r, s)
             for t in (0, 32, 96, 128, 256, 1024, 1056)
             for r in (0, 13, 27, 63, 64, 255, 256)
             for s in (0, 2048, 16384, 49152, 65536)]
    ts, rs, ss = zip(*cases)
    batch = cuda_occupancy_batch(list(ts), list(rs), list(ss), gpu)
    assert len(batch) == len(cases)
    for i, (t, r, s) in enumerate(cases):
        assert batch.at(i) == cuda_occupancy(t, r, s, gpu), (t, r, s)


# ---------------------------------------------------------------------------
# Dispatch under a GpuSpec: Table VII consistency, zero program runs
# ---------------------------------------------------------------------------

_PAPER_CASES = [
    ("atax", dict(m=2048, n=2048, dtype="float32")),
    ("bicg", dict(m=2048, n=2048, dtype="float32")),
    ("matvec", dict(m=2048, n=2048, dtype="float32")),
    ("jacobi3d", dict(z=64, y=64, x=64, dtype="float32")),
]


@pytest.mark.parametrize("kernel_id,sig", _PAPER_CASES)
@pytest.mark.parametrize("gpu_name", ["fermi_m2050", "kepler_k20",
                                      "maxwell_m40"])
def test_registry_params_match_suggest_cuda_params(kernel_id, sig, gpu_name):
    """The registry path and the standalone Table VII calculator must
    agree: the ranked winner is a member of the max-occupancy set T*."""
    gpu = resolve_target(gpu_name)
    db = TuningDatabase()
    params = tuning_cache.lookup_or_tune(kernel_id, db=db, spec=gpu, **sig)
    prof = get_spec(kernel_id).cuda
    sugg = suggest_cuda_params(prof.regs_for(gpu), prof.shmem_for(**sig),
                               gpu)
    assert params["threads"] in sugg["threads"]
    assert db.stats.tunes == 1
    # repeat dispatch is a pure cache hit — zero additional tunes
    again = tuning_cache.lookup_or_tune(kernel_id, db=db, spec=gpu, **sig)
    assert again == params and db.stats.tunes == 1


def test_gpu_and_tpu_targets_fully_isolated():
    """One kernel/signature under kepler_k20 and tpu_v5e: two records,
    two spec fingerprints, two memo entries, disjoint param spaces."""
    sig = dict(m=512, n=512, k=512, dtype="float32")
    db = TuningDatabase()
    p_gpu = tuning_cache.lookup_or_tune("matmul", db=db, spec="kepler_k20",
                                        **sig)
    p_tpu = tuning_cache.lookup_or_tune("matmul", db=db, spec="tpu-v5e",
                                        **sig)
    assert set(p_gpu) == {"threads"}
    assert set(p_tpu) == {"bm", "bn", "bk"}
    recs = list(db.records())
    assert len(recs) == 2
    assert len({r.key.spec_fingerprint for r in recs}) == 2
    # the warm-dispatch memo (default-db path) keys on the fingerprint
    tuning_cache.clear_dispatch_memo()
    with use_target("kepler_k20"):
        tuning_cache.lookup_or_tune("matmul", **sig)
    with use_target("tpu-v5e"):
        tuning_cache.lookup_or_tune("matmul", **sig)
    fps = {k[2] for k in registry_mod.dispatch_memo_keys()}
    assert fingerprint_spec(KEPLER_K20) in fps
    assert fingerprint_spec(TPU_V5E) in fps


def test_winning_threads_differ_across_gpu_generations():
    """The paper's core observation — the suggested launch params are
    chip-specific — must survive the registry path."""
    sig = dict(y=1024, x=1024, dtype="float32")
    db = TuningDatabase()
    winners = {g: tuning_cache.lookup_or_tune("stencil2d", db=db, spec=g,
                                              **sig)["threads"]
               for g in ("fermi_m2050", "kepler_k20", "maxwell_m40")}
    assert len(set(winners.values())) >= 2, winners


def test_all_infeasible_space_exports_strict_json(tmp_path):
    """flash_attention's R^u=64 exceeds Fermi's 63-register cap: every
    candidate is infeasible, the record ranks to predicted_s=+inf, and
    the JSONL export must still be strict JSON (null, not Infinity)."""
    sig = dict(b=2, h=4, sq=1024, skv=1024, d=128, causal=True,
               dtype="float32")
    db = TuningDatabase()
    params = tuning_cache.lookup_or_tune("flash_attention", db=db,
                                         spec="fermi_m2050", **sig)
    assert params["threads"] >= 32
    rec = next(iter(db.records()))
    assert math.isinf(rec.predicted_s)
    out = tmp_path / "fermi.jsonl"
    db.export_jsonl(str(out))
    boom = lambda c: (_ for _ in ()).throw(ValueError(c))
    payload = json.loads(out.read_text().splitlines()[0],
                         parse_constant=boom)
    assert payload["predicted_s"] is None
    db2 = TuningDatabase()
    assert db2.import_jsonl(str(out)) == 1
    rec2 = next(iter(db2.records()))
    assert math.isinf(rec2.predicted_s) and rec2.params == rec.params


# ---------------------------------------------------------------------------
# Shipped per-GPU databases
# ---------------------------------------------------------------------------


def test_gpu_targets_are_shipped():
    assert {"fermi-m2050", "kepler-k20", "maxwell-m40"} <= set(
        SHIPPED_TARGETS)


def test_gpu_pretune_verify_bit_identical(tmp_path):
    assert cli_main(["--db", str(tmp_path / "db"), "pretune", "--verify",
                     "--target", "kepler_k20"]) == 0


def test_gpu_dispatch_warms_from_shipped_db():
    db = tuning_cache.get_default_db()
    sig = dict(m=1024, n=1024, k=1024, dtype="float32")
    with use_target("kepler_k20"):
        params = tuning_cache.lookup_or_tune("matmul", **sig)
    assert "k20" in db.warmed_targets
    assert db.stats.tunes == 0            # served from pretuned/k20.jsonl
    assert set(params) == {"threads"}


# ---------------------------------------------------------------------------
# Pallas ops keep running while a GPU target is active (analysis-only)
# ---------------------------------------------------------------------------


def test_ops_run_correctly_under_gpu_target():
    import numpy as np
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    a = rng.standard_normal((64, 64), dtype=np.float32)
    x = rng.standard_normal((64, 1), dtype=np.float32)
    with use_target("kepler_k20"):
        y = ops.atax(a, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref.atax_ref(a, x)),
                               rtol=2e-4, atol=2e-4)
    # dispatch did record the CUDA ranking for the active GPU target
    db = tuning_cache.get_default_db()
    fps = {r.key.spec_fingerprint for r in db.records()
           if r.key.kernel_id == "atax"}
    assert fingerprint_spec(KEPLER_K20) in fps
