"""Per-kernel sweeps: shapes x dtypes x launch params vs the pure-jnp
oracle in interpret mode (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.atax import atax_pallas
from repro.kernels.bicg import bicg_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.jacobi3d import jacobi3d_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.matvec import matvec_pallas

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("m,n,k", [(128, 128, 128), (256, 512, 384),
                                   (512, 128, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bm,bn,bk", [(128, 128, 128), (256, 128, 128)])
def test_matmul(m, n, k, dtype, bm, bn, bk):
    if m % bm or n % bn or k % bk:
        pytest.skip("non-dividing block")
    a = jnp.asarray(RNG.standard_normal((m, k)), dtype)
    b = jnp.asarray(RNG.standard_normal((k, n)), dtype)
    out = matmul_pallas(a, b, bm=bm, bn=bn, bk=bk)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("m,n", [(256, 128), (512, 512), (1024, 256)])
@pytest.mark.parametrize("bm", [64, 128, 256])
def test_matvec(m, n, bm):
    a = jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((n, 1)), jnp.float32)
    out = matvec_pallas(a, x, bm=bm, bk=min(n, 128))
    np.testing.assert_allclose(out, ref.matvec_ref(a, x), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("m,n", [(256, 128), (512, 256), (1024, 512)])
@pytest.mark.parametrize("bm", [32, 128, 256])
def test_atax(m, n, bm):
    a = jnp.asarray(RNG.standard_normal((m, n)) / np.sqrt(n), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((n, 1)), jnp.float32)
    out = atax_pallas(a, x, bm=bm)
    np.testing.assert_allclose(out, ref.atax_ref(a, x), rtol=1e-3,
                               atol=1e-3)


@pytest.mark.parametrize("m,n", [(256, 128), (512, 256)])
@pytest.mark.parametrize("bm", [64, 256])
def test_bicg(m, n, bm):
    a = jnp.asarray(RNG.standard_normal((m, n)) / np.sqrt(n), jnp.float32)
    p = jnp.asarray(RNG.standard_normal((n, 1)), jnp.float32)
    r = jnp.asarray(RNG.standard_normal((m, 1)), jnp.float32)
    q, s = bicg_pallas(a, p, r, bm=bm)
    q2, s2 = ref.bicg_ref(a, p, r)
    np.testing.assert_allclose(q, q2, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(s, s2, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("z,y,x", [(8, 16, 32), (16, 32, 64), (32, 8, 128)])
@pytest.mark.parametrize("bz", [1, 2, 4, 8])
def test_jacobi3d(z, y, x, bz):
    if z % bz:
        pytest.skip("non-dividing block")
    u = jnp.asarray(RNG.standard_normal((z, y, x)), jnp.float32)
    out = jacobi3d_pallas(u, bz=bz)
    np.testing.assert_allclose(out, ref.jacobi3d_ref(u), rtol=1e-5,
                               atol=1e-5)


def test_jacobi3d_boundary_passthrough():
    u = jnp.asarray(RNG.standard_normal((8, 8, 128)), jnp.float32)
    out = np.asarray(jacobi3d_pallas(u, bz=2))
    ua = np.asarray(u)
    np.testing.assert_array_equal(out[0], ua[0])
    np.testing.assert_array_equal(out[-1], ua[-1])
    np.testing.assert_array_equal(out[:, 0, :], ua[:, 0, :])
    np.testing.assert_array_equal(out[:, :, -1], ua[:, :, -1])


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("s,bq,bkv", [(256, 128, 128), (512, 256, 128),
                                      (256, 256, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(causal, s, bq, bkv, dtype):
    b, h, d = 2, 3, 64
    q = jnp.asarray(RNG.standard_normal((b, h, s, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, h, s, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, h, s, d)), dtype)
    out = flash_attention_pallas(q, k, v, causal=causal, bq=bq, bkv=bkv)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_ops_defaults_dispatch():
    a = jnp.asarray(RNG.standard_normal((192, 160)), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((160, 1)), jnp.float32)
    np.testing.assert_allclose(ops.matvec(a, x), ref.matvec_ref(a, x),
                               rtol=2e-4, atol=2e-4)
    u = jnp.asarray(RNG.standard_normal((12, 16, 128)), jnp.float32)
    np.testing.assert_allclose(ops.jacobi3d(u), ref.jacobi3d_ref(u),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# input validation: real exceptions (asserts vanish under `python -O`)
# ---------------------------------------------------------------------------


def test_matmul_rejects_mismatched_inner_dims():
    a = jnp.ones((128, 64), jnp.float32)
    b = jnp.ones((128, 64), jnp.float32)      # 64 != 128
    with pytest.raises(ValueError, match=r"inner dimensions.*128"):
        matmul_pallas(a, b)


def test_pallas_kernels_reject_non_dividing_blocks():
    from repro.kernels.stencil2d import stencil2d_pallas
    a = jnp.ones((96, 96), jnp.float32)
    v = jnp.ones((96, 1), jnp.float32)
    cases = [
        (lambda: matmul_pallas(a, a, bm=40), "matmul_pallas"),
        (lambda: matvec_pallas(a, v, bm=40), "matvec_pallas"),
        (lambda: atax_pallas(a, v, bm=40), "atax_pallas"),
        (lambda: bicg_pallas(a, v, jnp.ones((96, 1), jnp.float32), bm=40),
         "bicg_pallas"),
        (lambda: jacobi3d_pallas(jnp.ones((6, 8, 128), jnp.float32), bz=4),
         "jacobi3d_pallas"),
        (lambda: flash_attention_pallas(
            jnp.ones((1, 1, 96, 64), jnp.float32),
            jnp.ones((1, 1, 96, 64), jnp.float32),
            jnp.ones((1, 1, 96, 64), jnp.float32), bq=40),
         "flash_attention_pallas"),
        (lambda: stencil2d_pallas(a, by=40), "stencil2d_pallas"),
    ]
    for call, name in cases:
        with pytest.raises(ValueError, match=name) as exc:
            call()
        # the error names the offending (shape, block) pair
        assert "does not divide" in str(exc.value), name


def test_pallas_kernels_reject_wrong_operand_shapes():
    a = jnp.ones((128, 64), jnp.float32)
    bad = jnp.ones((32, 1), jnp.float32)
    with pytest.raises(ValueError, match=r"x has shape \(32, 1\)"):
        matvec_pallas(a, bad)
    with pytest.raises(ValueError, match=r"x has shape"):
        atax_pallas(a, bad)
    with pytest.raises(ValueError, match=r"p has shape"):
        bicg_pallas(a, bad, jnp.ones((128, 1), jnp.float32))
    with pytest.raises(ValueError, match=r"k has shape"):
        flash_attention_pallas(jnp.ones((1, 2, 128, 64), jnp.float32),
                               jnp.ones((1, 1, 128, 64), jnp.float32),
                               jnp.ones((1, 1, 128, 64), jnp.float32))
