"""End-to-end behaviour tests for the paper's system.

1. The autotuner stack: static mode never executes, hybrid beats naive
   picks, calibration tightens the model, Spearman(static, measured) is
   positive on a real kernel sweep.
2. Training end-to-end: loss decreases on the synthetic stream.
3. Multi-device SPMD: an 8-device sub-mesh lowers the sharded train
   step, the HLO contains collectives, and the loop-aware analyzer sees
   them (runs in a subprocess so this process keeps 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KernelTuner, calibrate, default_tpu_model,
                        spearman)
from repro.kernels import make_tunable_atax, make_tunable_matmul

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# autotuner system behaviour
# ---------------------------------------------------------------------------


def test_static_mode_runs_nothing_and_prunes_everything():
    tk = make_tunable_atax(m=512, n=256)
    calls = []
    orig_build = tk.build
    tk.build = lambda p: calls.append(p) or orig_build(p)
    rep = KernelTuner(tk, repeats=1).tune(mode="static")
    assert calls == []
    assert rep.empirical_evals == 0
    assert rep.search_space_reduction == 1.0
    assert rep.best_params in tk.space.enumerate()


def test_hybrid_measures_only_shortlist():
    tk = make_tunable_matmul(m=512, n=512, k=512)   # 27-point space
    rep = KernelTuner(tk, repeats=1, keep_frac=0.25).tune(
        mode="hybrid", empirical_budget=2)
    assert rep.empirical_evals == 2
    assert rep.best_measured_s is not None
    assert rep.search_space_reduction > 0.5


def test_static_rank_correlates_with_measurement():
    tk = make_tunable_matmul(m=512, n=512, k=512)
    tuner = KernelTuner(tk, repeats=3)
    rep = tuner.tune(mode="empirical", empirical_budget=10)
    assert rep.spearman_static_vs_measured is not None
    assert rep.spearman_static_vs_measured > 0.3, rep.summary()


def test_calibration_reduces_error():
    tk = make_tunable_atax(m=512, n=256)
    tuner = KernelTuner(tk, repeats=2)
    pts = [(p, tuner._info(p).mix) for p in tk.space.enumerate()]
    from benchmarks.common import median_time
    inputs = tk.make_inputs()
    times = [median_time(tk.build(p), inputs, 2) for p, _ in pts]
    mixes = [m for _, m in pts]
    base = default_tpu_model(mode="sum")
    fit = calibrate(mixes, times, mode="sum")
    err_base = np.mean([abs(base.time(m) - t) / t
                        for m, t in zip(mixes, times)])
    err_fit = np.mean([abs(fit.time(m) - t) / t
                       for m, t in zip(mixes, times)])
    assert err_fit <= err_base + 1e-9


# ---------------------------------------------------------------------------
# end-to-end training
# ---------------------------------------------------------------------------


def test_training_loss_decreases():
    from repro.data import DataConfig, TokenStream
    from repro.distributed import make_train_step
    from repro.models import ModelConfig, build_model
    from repro.optim import AdamWConfig, init_adamw

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv=2, d_ff=128, vocab=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_adamw(params)
    step = jax.jit(make_train_step(
        model, AdamWConfig(peak_lr=1e-2, warmup_steps=5, decay_steps=100)),
        donate_argnums=(0, 1))
    stream = TokenStream(DataConfig(vocab=512, global_batch=8, seq_len=64))
    losses = []
    for s in range(25):
        b = {k: jnp.asarray(v) for k, v in stream.make_batch(s).items()}
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses[::6]
    assert all(np.isfinite(l) for l in losses)


# ---------------------------------------------------------------------------
# multi-device SPMD (subprocess: needs its own device count)
# ---------------------------------------------------------------------------

_SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from repro.configs import get_smoke
    from repro.core.hlo import collective_stats, module_mix, parse_hlo
    from repro.distributed import make_train_step, TrainStepConfig
    from repro.launch.specs import cell_inputs
    from repro.models import build_model
    from repro.models.config import ShapeSpec
    from repro.optim import AdamWConfig

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke("gemma-7b")
    model = build_model(cfg)
    shape = ShapeSpec("tiny_train", 64, 8, "train")
    args = cell_inputs(model, shape, mesh)
    step = make_train_step(model, AdamWConfig(), mesh=mesh,
                           step_cfg=TrainStepConfig(microbatches=2))
    with mesh:
        compiled = jax.jit(step).lower(*args).compile()
        text = compiled.as_text()
    mod = parse_hlo(text)
    coll = collective_stats(mod)
    mix = module_mix(mod)
    print(json.dumps({
        "collective_bytes": coll.total_bytes,
        "kinds": sorted(coll.by_kind_bytes),
        "flops": mix.mxu_flops,
    }))
""")


@pytest.mark.slow
def test_spmd_submesh_lowering_and_collectives():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", _SPMD_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["collective_bytes"] > 0
    assert "all-reduce" in rec["kinds"] or "all-gather" in rec["kinds"]
    assert rec["flops"] > 0
