"""Faithful-reproduction unit tests: the paper's own numbers.

Eqs. 1-5 / Table I / Table II / Table VII occ* values and Eq. 6 CPI
weights must reproduce the published arithmetic exactly.
"""
import math

import pytest

from repro.core import (FERMI_M2050, GPU_TABLE, IPC_TABLE, KEPLER_K20,
                        MAXWELL_M40, cpi, cuda_eq6_time, cuda_occupancy,
                        suggest_cuda_params)
from benchmarks.bench_table7_suggestions import (EXACT_ROWS, PAPER_OCC,
                                                 PAPER_RU, table7_cuda)


def test_table1_constants():
    assert FERMI_M2050.warps_per_mp == 48
    assert KEPLER_K20.blocks_per_mp == 16
    assert MAXWELL_M40.blocks_per_mp == 32
    assert FERMI_M2050.regs_per_block == 32768
    assert KEPLER_K20.reg_alloc_size == 256
    assert FERMI_M2050.regs_per_thread == 63
    assert MAXWELL_M40.threads_per_mp == 2048


def test_table2_ipc():
    assert IPC_TABLE["FPIns32"] == {"sm20": 32, "sm35": 192, "sm52": 128}
    assert IPC_TABLE["LogSinCos"]["sm20"] == 4
    assert IPC_TABLE["LdStIns"]["sm52"] == 64
    assert cpi("FPIns32", KEPLER_K20) == pytest.approx(1 / 192)


def test_occupancy_full_at_reasonable_config():
    # 256 threads, 32 regs/thread, no shared memory on Kepler: full occ.
    occ = cuda_occupancy(256, 32, 0, KEPLER_K20)
    assert occ.occupancy == pytest.approx(1.0)
    assert occ.active_warps == 64


def test_occupancy_register_limited():
    # Max registers per thread forces few blocks.
    occ = cuda_occupancy(1024, 255, 0, KEPLER_K20)
    assert occ.limiter == "regs"
    assert occ.occupancy < 0.5


def test_occupancy_illegal_registers():
    occ = cuda_occupancy(256, 300, 0, KEPLER_K20)  # > R_T^cc = 255
    assert occ.active_blocks == 0
    assert occ.occupancy == 0.0


def test_occupancy_shared_memory_limited():
    # one block's shared memory = the whole SM's: 1 active block.
    occ = cuda_occupancy(64, 16, 49152, FERMI_M2050)
    assert occ.g_shmem == 1
    assert occ.active_blocks == 1


def test_table7_occ_star_matches_paper():
    """occ* per Table VII: exact on the rows determined by published
    inputs (R^u, thread range); an upper bound on the two rows whose
    occ* embeds the kernel's unpublished shared-memory usage."""
    for row in table7_cuda():
        key = (row["kernel"], row["gpu"])
        if key in EXACT_ROWS:
            assert abs(row["occ_star"] - row["paper_occ_star"]) < 0.05, row
        else:
            assert row["occ_star"] >= row["paper_occ_star"] - 0.05, row


def test_table7_fermi_register_limited_rows_exact():
    """Hand-derivable rows: bicg/Fermi R=27 -> 36-warp cap -> 0.75;
    ex14FJ/Fermi R=30 -> 34-warp cap -> 0.71 (Eqs. 1-5 arithmetic)."""
    rows = {(\
        r["kernel"], r["gpu"]): r for r in table7_cuda()}
    assert rows[("bicg", "fermi")]["occ_star"] == pytest.approx(0.75,
                                                                abs=0.01)
    assert rows[("ex14FJ", "fermi")]["occ_star"] == pytest.approx(
        0.71, abs=0.015)


def test_eq6_linear_and_weighted():
    t = cuda_eq6_time(192.0, 0.0, 0.0, 0.0, KEPLER_K20)
    assert t == pytest.approx(1.0)  # 192 FP ops at 192 IPC = 1 cycle
    t2 = cuda_eq6_time(0.0, 32.0, 0.0, 0.0, KEPLER_K20)
    assert t2 == pytest.approx(1.0)  # 32 mem ops at 32 IPC = 1 cycle
    # doubling any class doubles its contribution (linearity)
    assert cuda_eq6_time(384.0, 0, 0, 0, KEPLER_K20) == pytest.approx(2.0)


def test_suggest_params_monotone_in_registers():
    lo = suggest_cuda_params(16, 0, MAXWELL_M40)
    hi = suggest_cuda_params(200, 0, MAXWELL_M40)
    assert lo["occ_star"] >= hi["occ_star"]


# ---------------------------------------------------------------------------
# All three Table I columns (not just the single-spec cases above)
# ---------------------------------------------------------------------------

_GPUS = [FERMI_M2050, KEPLER_K20, MAXWELL_M40]


@pytest.mark.parametrize("gpu", _GPUS, ids=lambda g: g.name)
def test_cuda_occupancy_over_every_table1_column(gpu):
    """Eqs. 1-5 must be well-formed on every architecture: a modest
    config reaches full occupancy, the three G_psi bounds are positive,
    and occupancy is always active_warps / warps_per_mp."""
    # 16 regs/thread keeps even Fermi's 32k register file off the
    # critical path (32 regs already caps it at 32 of 48 warps).
    occ = cuda_occupancy(256, 16, 0, gpu)
    assert occ.occupancy == pytest.approx(1.0)
    assert min(occ.g_warps, occ.g_regs, occ.g_shmem) > 0
    for threads in (64, 128, 512, gpu.threads_per_block):
        for regs in (0, 16, 63, gpu.regs_per_thread):
            o = cuda_occupancy(threads, regs, 0, gpu)
            assert 0.0 <= o.occupancy <= 1.0
            assert o.occupancy == pytest.approx(
                o.active_warps / gpu.warps_per_mp)
            assert o.active_blocks <= gpu.blocks_per_mp


@pytest.mark.parametrize("gpu", _GPUS, ids=lambda g: g.name)
def test_cuda_occupancy_illegal_configs_per_column(gpu):
    """Over-limit registers or shared memory zero the block count on
    every column (Eq. 4 case 1 / Eq. 5 illegal case)."""
    assert cuda_occupancy(256, gpu.regs_per_thread + 1, 0,
                          gpu).active_blocks == 0
    assert cuda_occupancy(256, 32, gpu.shmem_per_block + 1,
                          gpu).active_blocks == 0


@pytest.mark.parametrize("gpu", _GPUS, ids=lambda g: g.name)
def test_suggest_cuda_params_over_every_table1_column(gpu):
    """Table VII machinery on all three chips: a light kernel reaches
    full occupancy with positive headroom; the register-heavy variant
    never reports better occupancy than the light one."""
    lo = suggest_cuda_params(16, 0, gpu)
    assert lo["occ_star"] == pytest.approx(1.0)
    assert lo["threads"], "no thread size achieved occ*"
    assert lo["reg_headroom"] >= 0
    assert lo["shmem_star"] > 0
    hi = suggest_cuda_params(gpu.regs_per_thread, 1024, gpu)
    assert 0.0 < hi["occ_star"] <= lo["occ_star"]
    # every suggested thread size is a legal, warp-aligned block size
    for t in lo["threads"] + hi["threads"]:
        assert t % gpu.warp_size == 0
        assert t <= gpu.threads_per_block


def test_gpu_table_aliases_resolve_to_table1_columns():
    assert GPU_TABLE["fermi"] is FERMI_M2050
    assert GPU_TABLE["kepler"] is KEPLER_K20
    assert GPU_TABLE["maxwell"] is MAXWELL_M40
    assert len({id(GPU_TABLE[k]) for k in ("m2050", "k20", "m40")}) == 3
