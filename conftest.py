"""Root conftest: make `benchmarks` (and `src/repro` as fallback)
importable regardless of how pytest is invoked, and register the
project's custom pytest marks."""
import os
import sys

_ROOT = os.path.dirname(os.path.abspath(__file__))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (empirical timing sweeps, "
        "large interpret-mode kernels); deselect with -m 'not slow'")
